"""Logical-axis sharding rules (MaxText-style) and activation constraints.

Parameters and activations are annotated with *logical* axis names; a rule
table maps logical names to mesh axes.  `shard_activation` is a no-op unless
a rule context is active, so model code stays runnable on a single device.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "DECODE_RULES",
    "activate_rules",
    "shard_activation",
    "logical_to_pspec",
    "param_shardings",
]

# Baseline (paper-faithful FSDP+TP) rule set for the (pod, data, model) mesh.
# Values may be a single mesh axis, a tuple of axes, or None (replicate).
DEFAULT_RULES: dict[str, Any] = {
    # parameters
    "embed": "data",            # FSDP: shard the d_model dim of weights on data
    "mlp": "model",             # TP: FFN hidden
    "mlp_expert": "model",      # expert FFN hidden (experts may not divide mesh)
    "heads_x_dim": "model",     # fused (heads*head_dim) projection output
    "kv_x_dim": "model",        # fused (kv_heads*head_dim) — GSPMD pads if uneven
    "vocab": "model",
    "experts": "model",         # expert parallelism
    "layers": None,
    "state": None,
    "conv": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "heads": "model",
    "kv_heads": "model",
    "cache_seq": None,
    "cache_kv_heads": "model",
    "cache_head_dim": "model",  # fallback when kv_heads doesn't divide the axis
    "experts_act": "model",
}

# Decode: batch is small per-chip; keep FSDP off the fly-weight path.
DECODE_RULES = dict(DEFAULT_RULES)
DECODE_RULES.update({"embed": None})

# Named rule variants for the §Perf hillclimb (selected via dryrun --rules).
RULE_SETS: dict[str, dict] = {
    "default": DEFAULT_RULES,
    # no FSDP: pure tensor-parallel params (replicated over data)
    "tp_only": {**DEFAULT_RULES, "embed": None},
    # sequence-sharded activations (context parallelism on long sequences)
    "seq_data": {**DEFAULT_RULES, "seq": "data", "batch": ("pod",)},
    # shard the KV cache along sequence instead of kv-heads (flash-decode style)
    "kv_seq": {**DEFAULT_RULES, "cache_seq": "model", "cache_kv_heads": None},
    # expert-major: experts across the whole mesh
    "expert_wide": {**DEFAULT_RULES, "experts": ("data", "model"), "mlp_expert": None},
    # replicate KV heads over the model axis (GQA K < model-axis size causes
    # involuntary full rematerialization otherwise)
    "kv_rep": {**DEFAULT_RULES, "kv_heads": None, "kv_x_dim": None},
}

def filter_rules(rules: dict, mesh: Mesh) -> dict:
    """Drop mesh axes not present in `mesh` (e.g. 'pod' on single-pod)."""
    avail = set(mesh.axis_names)

    def filt(v):
        if v is None:
            return None
        if isinstance(v, (tuple, list)):
            kept = tuple(a for a in v if a in avail)
            return kept if kept else None
        return v if v in avail else None

    return {k: filt(v) for k, v in rules.items()}


_active_rules: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)
_active_mesh: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "repro_sharding_mesh", default=None
)


@contextlib.contextmanager
def activate_rules(rules: dict, mesh: Mesh):
    """Enable logical-axis constraints inside model code.

    Mesh axes missing from `mesh` (e.g. 'pod' on the single-pod mesh) are
    silently dropped from the rules.
    """
    avail = set(mesh.axis_names)

    def filt(v):
        if v is None:
            return None
        if isinstance(v, (tuple, list)):
            kept = tuple(a for a in v if a in avail)
            return kept if kept else None
        return v if v in avail else None

    tok_r = _active_rules.set({k: filt(v) for k, v in rules.items()})
    tok_m = _active_mesh.set(mesh)
    try:
        yield
    finally:
        _active_rules.reset(tok_r)
        _active_mesh.reset(tok_m)


def logical_to_pspec(
    axes: tuple, rules: dict, shape: tuple | None = None, mesh: Mesh | None = None
) -> P:
    """Translate logical axis names into a PartitionSpec.

    If `shape` and `mesh` are given, any assignment whose mesh-axis product
    does not divide the dimension falls back to the largest divisible subset
    (pjit *argument* shardings require exact divisibility, unlike internal
    with_sharding_constraint).  A mesh axis is used at most once per tensor.
    """
    sizes = dict(mesh.shape) if mesh is not None else {}
    out = []
    used: set[str] = set()
    for i, name in enumerate(axes):
        if name is None:
            out.append(None)
            continue
        v = rules.get(name)
        if v is None:
            out.append(None)
            continue
        vv = tuple(v) if isinstance(v, (tuple, list)) else (v,)
        vv = tuple(a for a in vv if a not in used)
        if shape is not None and mesh is not None and vv:
            dim = shape[i]

            def divisible(cand: tuple) -> bool:
                n = 1
                for a in cand:
                    n *= sizes[a]
                return dim % n == 0

            if not divisible(vv):
                # largest divisible prefix, then single axes in order
                cand: tuple = ()
                for j in range(len(vv) - 1, 0, -1):
                    if divisible(vv[:j]):
                        cand = vv[:j]
                        break
                if not cand:
                    for a in vv:
                        if divisible((a,)):
                            cand = (a,)
                            break
                vv = cand
        used.update(vv)
        if not vv:
            out.append(None)
        elif len(vv) == 1:
            out.append(vv[0])
        else:
            out.append(vv)
    return P(*out)


def shard_activation(x: jax.Array, axes: tuple) -> jax.Array:
    """Apply with_sharding_constraint from logical axes; identity w/o context."""
    rules = _active_rules.get()
    mesh = _active_mesh.get()
    if rules is None or mesh is None:
        return x
    if x.ndim != len(axes):
        return x
    spec = logical_to_pspec(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_shardings(meta_tree: Any, mesh: Mesh, rules: dict) -> Any:
    """Tree of NamedShardings from a ParamMeta tree (shape-aware fallback)."""
    from repro.models.module import ParamMeta

    frules = filter_rules(rules, mesh)

    def one(meta: ParamMeta):
        return NamedSharding(mesh, logical_to_pspec(meta.axes, frules, meta.shape, mesh))

    return jax.tree_util.tree_map(one, meta_tree, is_leaf=lambda x: isinstance(x, ParamMeta))
