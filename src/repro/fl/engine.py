"""Federated runtime: wires jitted JAX client gradients into the
Generalized-AsyncSGD server loop (repro.core.async_sgd).

The engine owns:
  * a client set — each client holds a data shard and a jitted grad fn,
  * the sampling policy — uniform / Jackson-optimal / physical-time-optimal
    (computed from the client speeds via repro.core.sampling),
  * the server algorithms — Generalized AsyncSGD, AsyncSGD, FedBuff, FedAvg,
  * metrics — accuracy/loss vs CS steps *and* physical time, per-node delays.

This is the paper's deep-learning experiment (§5) as a library.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import (
    BoundConstants,
    ServerConfig,
    SimConfig,
    export_stream,
    jit_fused_runner,
    jit_runner,
    optimize_two_cluster,
    run_favano,
    run_fedavg,
    run_fedbuff,
    run_generalized_async_sgd,
    step_scales,
)
from repro.data.pipeline import FederatedClassification, make_client_speeds

__all__ = [
    "MLPClassifier",
    "FLClients",
    "DeviceFLClients",
    "DeviceTaskClients",
    "TaskSetup",
    "ClassificationTask",
    "LMTask",
    "FLRun",
    "MatrixResult",
    "run_experiment",
    "run_matrix",
    "sampling_for",
]


# ------------------------------------------------------------------ #
# a small classifier in the same param-meta system as the big models
# ------------------------------------------------------------------ #
class MLPClassifier:
    """2-hidden-layer MLP; the FL-scale model (paper used ResNet20/CIFAR)."""

    def __init__(self, dim: int, num_classes: int, hidden: int = 128, seed: int = 0):
        k = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(k, 3)
        s1, s2 = 1.0 / np.sqrt(dim), 1.0 / np.sqrt(hidden)
        self.init_params = {
            "w1": jax.random.normal(k1, (dim, hidden), jnp.float32) * s1,
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (hidden, hidden), jnp.float32) * s2,
            "b2": jnp.zeros((hidden,), jnp.float32),
            "w3": jax.random.normal(k3, (hidden, num_classes), jnp.float32) * s2,
            "b3": jnp.zeros((num_classes,), jnp.float32),
        }

    @staticmethod
    def logits(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        h = jax.nn.relu(h @ params["w2"] + params["b2"])
        return h @ params["w3"] + params["b3"]

    @staticmethod
    def loss(params, batch):
        lg = MLPClassifier.logits(params, batch["x"])
        lp = jax.nn.log_softmax(lg)
        return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None], axis=-1))


class FLClients:
    """GradientSource over a federated dataset with one jitted grad fn."""

    def __init__(self, data: FederatedClassification, model: MLPClassifier, batch_size: int = 128):
        self.data = data
        self.model = model
        self.batch_size = batch_size
        self._grad = jax.jit(jax.grad(model.loss))
        self.grad_calls = 0

    def grad(self, client_id: int, params, server_step: int):
        batch = self.data.client_batch(client_id, self.batch_size)
        self.grad_calls += 1
        return self._grad(params, {"x": jnp.asarray(batch["x"]), "y": jnp.asarray(batch["y"])})


class DeviceFLClients:
    """Device-resident gradient source for the compiled scan engine.

    All client shards live on device as stacked (n, m, ...) arrays
    (`FederatedClassification.device_shards`); `device_grad` is traceable —
    the client id and server step arrive as abstract scalars.  Minibatches
    are contiguous windows of the shard at pre-drawn random offsets (the
    shard rows are iid, so a window is an iid batch): one table lookup plus
    one `dynamic_slice` per step, instead of a per-step PRNG fold and a
    scattered row gather — the same pre-drawn-block idiom as the event
    simulator, and the difference between ~60us and ~20us per scan step.
    """

    OFFSET_BLOCK = 8192  # pre-drawn window offsets, reused cyclically

    def __init__(
        self,
        data: FederatedClassification,
        model: MLPClassifier,
        batch_size: int = 128,
        shard_size: int = 1024,
        seed: int = 0,
    ):
        if batch_size > shard_size:
            raise ValueError("batch_size must be <= shard_size")
        xs, ys = data.device_shards(shard_size)
        self.x = jnp.asarray(xs)
        self.y = jnp.asarray(ys)
        self.batch_size = batch_size
        self.model = model
        self._starts = jax.random.randint(
            jax.random.PRNGKey(seed),
            (self.OFFSET_BLOCK,),
            0,
            shard_size - batch_size + 1,
        )
        self._loss_grad = jax.grad(model.loss)

    def device_grad(self, client_id, params, server_step):
        start = self._starts[server_step % self.OFFSET_BLOCK]
        B, D = self.batch_size, self.x.shape[-1]
        x = jax.lax.dynamic_slice(self.x, (client_id, start, 0), (1, B, D))[0]
        y = jax.lax.dynamic_slice(self.y, (client_id, start), (1, B))[0]
        return self._loss_grad(params, {"x": x, "y": y})


class DeviceTaskClients:
    """Device-resident gradient source for an arbitrary ``(loss_fn, params)``.

    The model-generic counterpart of `DeviceFLClients`: any loss
    ``loss_fn(params, batch) -> scalar`` over a dict batch, with the
    per-client datasets stacked as device-resident ``(n, m, ...)`` arrays.
    ``device_grad`` is traceable (client id / server step arrive as abstract
    scalars) and uses the same pre-drawn-window idiom: one offset-table
    lookup plus one `jax.lax.dynamic_slice` per leaf per step.

    It also exposes a host ``grad`` for the per-event Python loop: the SAME
    jitted ``device_grad`` called with concrete scalars, so the Python
    oracle consumes bit-identical minibatches to the compiled engine —
    which is what makes exact scan-vs-python parity checks possible for
    real models (the LM path), not just the MLP.
    """

    OFFSET_BLOCK = 8192  # pre-drawn window offsets, reused cyclically

    def __init__(self, loss_fn, shards: dict, batch_size: int, seed: int = 0):
        self.shards = {k: jnp.asarray(v) for k, v in shards.items()}
        first = next(iter(self.shards.values()))
        self.n_clients, shard_size = int(first.shape[0]), int(first.shape[1])
        for k, v in self.shards.items():
            if v.shape[:2] != (self.n_clients, shard_size):
                raise ValueError(f"shard {k!r}: leading dims must agree")
        if batch_size > shard_size:
            raise ValueError("batch_size must be <= shard size")
        self.batch_size = int(batch_size)
        self.loss_fn = loss_fn
        self._starts = jax.random.randint(
            jax.random.PRNGKey(seed),
            (self.OFFSET_BLOCK,),
            0,
            shard_size - batch_size + 1,
        )
        self._loss_grad = jax.grad(loss_fn)
        self._jit_grad = jax.jit(self.device_grad)
        self.grad_calls = 0

    def client_batch(self, client_id, server_step) -> dict:
        start = self._starts[server_step % self.OFFSET_BLOCK]
        B = self.batch_size

        def window(a):
            starts = (client_id, start) + (0,) * (a.ndim - 2)
            sizes = (1, B) + a.shape[2:]
            return jax.lax.dynamic_slice(a, starts, sizes)[0]

        return {k: window(v) for k, v in self.shards.items()}

    def device_grad(self, client_id, params, server_step):
        return self._loss_grad(params, self.client_batch(client_id, server_step))

    def grad(self, client_id, params, server_step):
        # per-event Python loop entry: same jitted computation, concrete ids
        self.grad_calls += 1
        return self._jit_grad(jnp.int32(client_id), params, jnp.int32(server_step))


# ------------------------------------------------------------------ #
def sampling_for(flc: FLConfig, mu: np.ndarray, constants: BoundConstants | None = None) -> np.ndarray:
    """Sampling probabilities per the configured policy."""
    n = flc.n_clients
    if flc.sampling == "uniform":
        return np.full(n, 1.0 / n)
    k = constants or BoundConstants(C=flc.concurrency, T=flc.server_steps)
    mu_f, mu_s = float(mu.max()), float(mu.min())
    n_f = int(np.sum(mu > (mu_f + mu_s) / 2))
    if mu_f == mu_s or n_f in (0, n):
        return np.full(n, 1.0 / n)
    if flc.sampling == "optimal":
        res = optimize_two_cluster(mu_f, mu_s, n, n_f, k)
    elif flc.sampling == "physical_time":
        from repro.core import optimize_physical_time

        res = optimize_physical_time(mu_f, mu_s, n, n_f, k)
    else:
        raise ValueError(flc.sampling)
    # res.p has fast-first layout; map onto actual fast/slow indices
    p = np.empty(n)
    p_fast, p_slow = res.p[0], res.p[-1]
    p[mu > (mu_f + mu_s) / 2] = p_fast
    p[mu <= (mu_f + mu_s) / 2] = p_slow
    return p / p.sum()


@dataclass
class FLRun:
    name: str
    eval_steps: np.ndarray
    eval_acc: np.ndarray
    eval_times: np.ndarray
    mean_delays: np.ndarray | None = None
    final_params: Any = None
    extras: dict = field(default_factory=dict)


def _accuracy_fn(model: MLPClassifier, data: FederatedClassification, batch: int = 2048):
    """Jitted eval-set accuracy; returns a device scalar so it is usable both
    as a host callback (python engine) and inside the compiled scan engine."""
    ev = data.eval_batch(batch)
    x, y = jnp.asarray(ev["x"]), jnp.asarray(ev["y"])

    @jax.jit
    def acc(params):
        return jnp.mean(jnp.argmax(MLPClassifier.logits(params, x), -1) == y)

    return acc


@dataclass
class TaskSetup:
    """What a task hands the engine: initial params, a device gradient
    source (traceable ``device_grad`` + host ``grad``), and a jitted eval
    fn returning a device scalar (accuracy for classification, loss for
    LM)."""

    params: Any
    clients: Any
    eval_fn: Callable
    model: Any = None


@dataclass
class ClassificationTask:
    """The paper's §5 task: an MLP over `FederatedClassification` shards.

    This is the default when no task is passed — `run_experiment` /
    `run_matrix` behave exactly as before the task abstraction existed.
    """

    batch_size: int = 128
    shard_size: int = 1024
    hidden: int = 128

    def cache_key(self):
        return ("classification", self.batch_size, self.shard_size, self.hidden)

    def build(self, data: FederatedClassification, seed: int, n_clients: int) -> TaskSetup:
        if data is None:
            raise ValueError("ClassificationTask requires a dataset")
        model = MLPClassifier(data.dim, data.num_classes, hidden=self.hidden, seed=seed)
        clients = DeviceFLClients(
            data, model, batch_size=self.batch_size, shard_size=self.shard_size,
            seed=seed,
        )
        return TaskSetup(
            params=model.init_params,
            clients=clients,
            eval_fn=_accuracy_fn(model, data),
            model=model,
        )


@dataclass
class LMTask:
    """Async-LM pre-training task: ``api.loss_fn`` over a real ModelConfig.

    Each client holds a fixed non-iid shard materialized from its own
    `SyntheticLMStream` (seed ``seed*1000 + i`` — the same per-client
    streams as the historical Python LM loop), stacked to device-resident
    ``(n, m, S)`` token/label arrays for the compiled engine.  The eval
    metric is the loss on a held-out stream (seed 9999), as a jitted device
    scalar, so it works both as a host callback and inside the scan.

    With ``cfg.use_pallas`` the gradient runs through the Pallas
    flash-attention / SSD / grouped-matmul kernels, whose backward passes
    are the jnp-reference VJPs (`repro.kernels.flash_attention` et al.).
    """

    cfg: Any                      # repro.configs.base.ModelConfig (hashable)
    batch_size: int = 4
    seq_len: int = 64
    shard_size: int = 256
    eval_batch: int = 16

    def cache_key(self):
        return ("lm", self.cfg, self.batch_size, self.seq_len,
                self.shard_size, self.eval_batch)

    def build(self, data, seed: int, n_clients: int) -> TaskSetup:
        from repro.data.pipeline import SyntheticLMStream
        from repro.models import api
        from repro.models.module import init_params

        cfg = self.cfg
        toks = np.empty((n_clients, self.shard_size, self.seq_len), np.int32)
        labs = np.empty_like(toks)
        for i in range(n_clients):
            stream = SyntheticLMStream(cfg.vocab_size, self.seq_len,
                                       seed=seed * 1000 + i)
            b = stream.batch(self.shard_size)
            toks[i], labs[i] = b["tokens"], b["labels"]

        def loss(params, batch):
            return api.loss_fn(params, batch, cfg)[0]

        clients = DeviceTaskClients(
            loss, {"tokens": toks, "labels": labs},
            batch_size=self.batch_size, seed=seed,
        )
        params0 = init_params(api.model_meta(cfg), jax.random.PRNGKey(seed))
        ev_stream = SyntheticLMStream(cfg.vocab_size, self.seq_len, seed=9999)
        ev = {k: jnp.asarray(v) for k, v in ev_stream.batch(self.eval_batch).items()}
        eval_fn = jax.jit(lambda params: loss(params, ev))
        return TaskSetup(params=params0, clients=clients, eval_fn=eval_fn)


def _cached_fl_setup(data: FederatedClassification | None, seed: int,
                     task=None, n_clients: int | None = None) -> TaskSetup:
    """Task setup (params, device clients, eval fn) memoized per (seed, task).

    The compiled-engine memoization (`jit_runner` / `jit_fused_runner`) keys
    on the gradient-source and eval-fn *objects*; rebuilding them per
    `run_matrix` call would defeat it.  Caching them on the dataset (or,
    for dataset-free tasks like `LMTask`, on the task object) lets sweeps
    (e.g. over eval cadence, eta or sampling policies) reuse one compiled
    program — and the cache dies with its owner instead of pinning device
    shards globally.  The key includes ``task.cache_key()`` — the dataset
    alone is NOT enough: two different tasks (or model configs) over the
    same data must not silently share one model.
    """
    task = task if task is not None else ClassificationTask()
    owner = data if data is not None else task
    cache = owner.__dict__.setdefault("_fl_setup_cache", {})
    key = (seed, task.cache_key())
    if key not in cache:
        n = n_clients if n_clients is not None else getattr(data, "n_clients", None)
        cache[key] = task.build(data, seed, n)
    return cache[key]


def run_experiment(
    flc: FLConfig,
    method: str,
    eta: float = 0.05,
    eval_every: int = 10,
    data: FederatedClassification | None = None,
    engine: str | None = None,
    task=None,
    faults=None,
    guard=None,
    serving=None,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    resume: bool = False,
) -> FLRun:
    """One training run of {gen_async, async_sgd, fedbuff, fedavg, favano}.

    ``task`` picks the model/workload (default `ClassificationTask` — the
    paper's MLP): any object with ``cache_key()`` and ``build(data, seed,
    n_clients) -> TaskSetup`` plugs in, e.g. `LMTask` for async LM
    pre-training of the real transformer/Mamba2 configs through the same
    queueing engine (``eval_acc`` then carries eval *loss*).

    ``engine`` (default: ``flc.engine``) picks the server loop for the
    asynchronous methods: "python" is the per-event reference loop, "scan"
    the compiled device-resident engine (one XLA program for the whole run).
    ``flc.stream`` picks the scan engine's event source ("host" replay vs
    fused "device" generation — the latter implies the scan engine and is
    required for ``flc.adaptive`` sampling).  ``flc.block_size`` turns on
    the micro-blocked replay (an int E, or "auto" to select E from the
    measured conflict rates), ``flc.segmentation`` its cut placement, and
    ``flc.devices`` lane-shards each block's E gradient lanes across that
    many devices — see ``docs/architecture.md`` for the decision matrix.
    The synchronous baselines (fedavg, favano) always use the Python loop.

    Robustness knobs (async methods): ``faults`` injects client churn /
    crashes / straggler timeouts (`repro.core.FaultConfig`); ``guard``
    rejects divergent or over-stale updates (`repro.core.GuardConfig`);
    ``serving`` merges an open inference-request stream into the device
    event race and serves from the snapshot ring
    (`repro.core.ServingConfig`, requires ``flc.stream == "device"``);
    ``ckpt_dir`` + ``ckpt_every`` checkpoint the full engine state every
    ``ckpt_every`` CS steps (scan engine), and ``resume=True`` restores the
    latest checkpoint and continues — a killed run resumed this way produces
    the bitwise-identical final model.
    """
    if flc.stream == "device":
        if engine == "python":
            raise ValueError("stream='device' requires the scan engine")
        engine = "scan"
    else:
        engine = flc.engine if engine is None else engine
    if engine not in ("python", "scan"):
        raise ValueError(engine)
    classification = task is None or isinstance(task, ClassificationTask)
    if classification:
        data = data or FederatedClassification(n_clients=flc.n_clients, seed=flc.seed)
    mu = make_client_speeds(flc.n_clients, flc.frac_fast, flc.speed_ratio, seed=flc.seed)

    async_method = method in ("gen_async", "async_sgd", "fedbuff")
    use_scan = engine == "scan" and async_method
    if flc.adaptive and async_method and not use_scan:
        raise ValueError(
            "adaptive sampling requires engine='scan' with stream='device'"
        )
    if use_scan or not classification:
        # device-resident task setup; the Python loop for non-classification
        # tasks drives the SAME jitted gradient via the host `grad` entry
        setup = _cached_fl_setup(data, flc.seed, task, n_clients=flc.n_clients)
        w0, clients, acc_fn = setup.params, setup.clients, setup.eval_fn
    else:
        # per-event Python loop for classification: streaming host batches
        model = MLPClassifier(data.dim, data.num_classes, seed=flc.seed)
        clients = FLClients(data, model)
        acc_fn = _accuracy_fn(model, data)
        w0 = model.init_params

    base = ServerConfig(
        n=flc.n_clients,
        C=flc.concurrency,
        T=flc.server_steps,
        eta=eta,
        mu=mu,
        service=flc.service,
        seed=flc.seed,
        eval_every=eval_every,
        engine="scan" if use_scan else "python",
        stream=flc.stream if use_scan else "host",
        sparse=flc.sparse,
        adaptive=flc.adaptive if use_scan else False,
        refresh_every=flc.refresh_every,
        block_size=flc.block_size if use_scan else 1,
        devices=flc.devices if use_scan else 1,
        segmentation=flc.segmentation,
        faults=faults,
        guard=guard,
        serving=serving,
        ckpt_dir=ckpt_dir,
        ckpt_every=ckpt_every,
        resume=resume,
        scenario=flc.scenario,
    )

    if method == "gen_async":
        p = sampling_for(flc, mu)
        cfg = replace(base, p=p, weighting="importance")
        w, tr = run_generalized_async_sgd(w0, clients, cfg, eval_fn=acc_fn)
    elif method == "async_sgd":
        cfg = replace(base, weighting="plain")
        w, tr = run_generalized_async_sgd(w0, clients, cfg, eval_fn=acc_fn)
    elif method == "fedbuff":
        cfg = replace(base, weighting="plain")
        w, tr = run_fedbuff(w0, clients, cfg, Z=flc.fedbuff_Z, eval_fn=acc_fn)
    elif method == "fedavg":
        cfg = replace(base, weighting="plain")
        w, tr = run_fedavg(w0, clients, cfg, eval_fn=acc_fn)
    elif method == "favano":
        cfg = replace(base, weighting="plain")
        w, tr = run_favano(w0, clients, cfg,
                           period=1.0 / float(np.median(mu)), eval_fn=acc_fn)
    else:
        raise ValueError(method)

    ev_steps = np.asarray(tr.eval_steps)
    times = (
        np.asarray([tr.times[min(s - 1, len(tr.times) - 1)] for s in tr.eval_steps])
        if len(tr.eval_steps)
        else np.array([])
    )
    delays = None
    if tr.delays is not None:
        delays = np.array([np.mean(d) if d else np.nan for d in tr.delays])
    grad_calls = flc.server_steps if use_scan else clients.grad_calls
    extras = {"grad_calls": grad_calls, "engine": "scan" if use_scan else "python"}
    extras.update(getattr(tr, "extras", {}))  # device stream: p_final, p_traj, ...
    if delays is None and "mean_delays" in extras:
        delays = extras.pop("mean_delays")
    return FLRun(
        name=method,
        eval_steps=ev_steps,
        eval_acc=np.asarray(tr.eval_values),
        eval_times=times,
        mean_delays=delays,
        final_params=w,
        extras=extras,
    )


# ------------------------------------------------------------------ #
# scenario matrix: seeds x sampling policies x heterogeneity levels
# ------------------------------------------------------------------ #
@dataclass
class MatrixResult:
    """Output of `run_matrix`: eval curves over the full scenario grid."""

    seeds: tuple[int, ...]
    policies: tuple[str, ...]
    speed_ratios: tuple[float, ...]
    eval_steps: np.ndarray    # (n_evals,) CS steps at which accuracy was taken
    eval_acc: np.ndarray      # (S, P, H, n_evals)
    eval_times: np.ndarray    # (S, P, H, n_evals) physical time at each eval
    final_acc: np.ndarray     # (S, P, H)
    p_vectors: np.ndarray     # (P, H, n) sampling vector per (policy, ratio)
    extras: dict = field(default_factory=dict)  # device stream: p_final,
                                                # mean_delays, comp, ...


def run_matrix(
    flc: FLConfig,
    seeds: tuple[int, ...] = (0, 1, 2),
    policies: tuple[str, ...] = ("uniform", "optimal", "physical_time"),
    speed_ratios: tuple[float, ...] | None = None,
    eta: float = 0.05,
    eval_every: int = 50,
    data: FederatedClassification | None = None,
    stream: str | None = None,
    block_size: int | str | None = None,
    devices: int | None = None,
    segmentation: str | None = None,
    task=None,
    scenario: str | None = None,
) -> MatrixResult:
    """Run the whole scenario grid in ONE compiled call.

    ``task`` picks the model/workload exactly as in `run_experiment`
    (default: the paper's classification MLP; `LMTask` trains the real LM
    configs, with ``eval_acc``/``final_acc`` then carrying eval loss).

    ``stream`` (default ``flc.stream``) picks the event source:

      "host"    event streams (one per scenario) are pre-simulated on the
                host — O(T) Python each, serial in the number of scenarios —
                then the scan engine is `jax.vmap`-ed over the stacked
                arrays.
      "device"  zero host pre-simulation: the fused engine generates every
                scenario's closed-network events inside the one compiled
                program, vmapped over (mu, p, key).  Exponential service
                only; supports ``flc.adaptive`` sampling (the "uniform"
                policy rows then double as adaptive-from-uniform runs).

    ``block_size`` (default ``flc.block_size``) turns on the blocked engine:
    with E > 1 the host path replays conflict-free event micro-blocks
    (`queue_sim.export_blocks` + the batched `engine_scan` block step, with
    eval points forced onto block boundaries) and the device path advances E
    CS steps per scan iteration — both trajectory-equivalent to E=1.
    ``"auto"`` selects E from the conflict rates measured on the actual
    per-scenario streams (`queue_sim.select_block_size`) — host path — or
    on a short device-generated probe (device path).

    ``segmentation`` (default ``flc.segmentation``) picks the cut placement
    ("greedy" | "dp"); ``devices`` (default ``flc.devices``) lane-shards
    each micro-block's E gradient lanes across that many devices — the
    scenario batch then shares a scenario × lane 2-D mesh with whatever
    device budget remains (device stream), or a 1-D lane mesh with the
    scenario axis vmapped per device (host stream).

    The model/dataset are shared across scenarios; only the queueing clock,
    sampling vector and event realization differ.  Pass a persistent
    ``data`` object to reuse the compiled program across calls (the jitted
    runner is memoized on the dataset's cached gradient source, and the
    eval cadence is a static call-time argument, so sweeping ``eval_every``
    does not rebuild the runner).
    """
    stream = flc.stream if stream is None else stream
    if stream not in ("host", "device"):
        raise ValueError(stream)
    # one ScenarioConfig per matrix: the (seed × policy × ratio) grid vmaps
    # within a scenario (ScenarioRates shapes are static per compile); sweep
    # scenarios across calls (benchmarks/engine.py --scenarios)
    from repro.core.scenario import get_scenario

    sc = get_scenario(scenario if scenario is not None else flc.scenario)
    if sc is not None and not sc.enabled:
        sc = None
    block_size = flc.block_size if block_size is None else block_size
    if block_size != "auto":
        block_size = int(block_size)
    lane = max(int(flc.devices if devices is None else devices), 1)
    segmentation = flc.segmentation if segmentation is None else segmentation
    speed_ratios = (flc.speed_ratio,) if speed_ratios is None else tuple(speed_ratios)
    seeds, policies = tuple(seeds), tuple(policies)
    if task is None or isinstance(task, ClassificationTask):
        data = data or FederatedClassification(n_clients=flc.n_clients, seed=flc.seed)
    setup = _cached_fl_setup(data, flc.seed, task, n_clients=flc.n_clients)
    clients, acc_fn = setup.clients, setup.eval_fn

    n, C, T = flc.n_clients, flc.concurrency, flc.server_steps
    S, P, H = len(seeds), len(policies), len(speed_ratios)
    B = S * P * H
    # (policy, ratio) -> (mu, p) is seed-independent: compute each cell once
    mus = {hi: make_client_speeds(n, flc.frac_fast, ratio, seed=flc.seed)
           for hi, ratio in enumerate(speed_ratios)}
    p_vectors = np.empty((P, H, n))
    for pi, pol in enumerate(policies):
        for hi in range(H):
            p_vectors[pi, hi] = sampling_for(replace(flc, sampling=pol), mus[hi])
    w0 = setup.params
    extras: dict = {"stream": stream}

    if stream == "device":
        if flc.service != "exp":
            raise ValueError(
                "stream='device' supports exponential service only; use "
                "stream='host' for service='det'"
            )
        mu_b = np.empty((B, n))
        p_b = np.empty((B, n))
        keys = []
        b = 0
        for seed in seeds:
            base_key = jax.random.PRNGKey(seed)
            for pi in range(P):
                for hi in range(H):
                    mu_b[b], p_b[b] = mus[hi], p_vectors[pi, hi]
                    keys.append(jax.random.fold_in(base_key, pi * H + hi))
                    b += 1
        # shard scenarios across devices when they divide evenly (e.g. CPU
        # with --xla_force_host_platform_device_count, or a TPU/GPU pod) —
        # the host-export path is serial Python and cannot
        D = jax.device_count()
        if sc is not None:
            if block_size == "auto":
                block_size = 1  # scenario stream is per-event
            elif block_size > 1:
                raise ValueError("scenario= requires block_size=1")
        if block_size == "auto":
            # same resolution policy as the single-run driver (_run_scan):
            # probe with the configured scenario, not a fresh exp stream
            from repro.core.async_sgd import _auto_block_size, _probe_stream_slots

            block_size = _auto_block_size(
                _probe_stream_slots(mu_b[0], p_b[0], C, T, int(seeds[0]),
                                    scenario=sc),
                lane,
            )
        if lane > 1:
            # scenario × lane 2-D mesh: lanes split each micro-block's
            # gradient batch, leftover devices shard the scenario batch
            rem = D // lane
            shard = rem if (rem > 1 and B % rem == 0) else 1
        else:
            shard = D if (D > 1 and B % D == 0) else 1
        # the scenario matrix stays on the dense stream: scenarios vmap over
        # full (n,) mu/p rows, while the sparse O(C) path needs a static
        # per-scenario ClassSpec — single runs pick it up via
        # ServerConfig.sparse (run_fl), where n can be orders larger
        runner = jit_fused_runner(
            clients.device_grad, n, C, T,
            vmap_scenarios=True,
            shard_devices=shard,
            lane_devices=lane,
            weighting=flc.weighting,
            eval_fn=acc_fn,
            eval_every=eval_every,
            adaptive=flc.adaptive,
            refresh_every=flc.refresh_every,
            block_size=block_size,
            scenario=sc,
        )
        if lane > 1:
            shard = 1  # shard_map consumes flat (B, ...) batches — no reshape
        args = (jnp.asarray(mu_b), jnp.asarray(p_b), jnp.stack(keys))
        if shard > 1:
            args = tuple(a.reshape((shard, B // shard) + a.shape[1:]) for a in args)
        w_final, evals, dev_extras = runner(w0, *args, eta)
        if shard > 1:
            unshard = lambda x: np.asarray(x).reshape((B,) + x.shape[2:])
            w_final = jax.tree_util.tree_map(
                lambda x: jnp.asarray(x).reshape((B,) + x.shape[2:]), w_final
            )
            evals = unshard(evals)
            dev_extras = {k: unshard(v) for k, v in dev_extras.items()}
        t_phys = np.asarray(dev_extras["t"], np.float64)
        comp = np.asarray(dev_extras["comp"], np.float64)
        extras.update(
            p_final=np.asarray(dev_extras["p_final"], np.float64).reshape(S, P, H, n),
            mean_delays=(np.asarray(dev_extras["delay_sum"], np.float64)
                         / np.maximum(comp, 1.0)).reshape(S, P, H, n),
            comp=comp.reshape(S, P, H, n),
            occ_mean=np.asarray(dev_extras["occ_mean"], np.float64).reshape(S, P, H, n),
        )
    else:
        streams = []
        t_phys = np.empty((B, T))
        b = 0
        for seed in seeds:
            for pi in range(P):
                for hi in range(H):
                    p = p_vectors[pi, hi]
                    es = export_stream(
                        SimConfig(mu=mus[hi], p=p, C=C, T=T,
                                  service=flc.service, seed=seed,
                                  scenario=sc)
                    )
                    streams.append((es, step_scales(es, eta, p, flc.weighting)))
                    t_phys[b] = es.t
                    b += 1
        if block_size == "auto":
            # same resolution policy as the single-run driver (_run_scan),
            # measured jointly over the actual per-scenario streams
            from repro.core.async_sgd import _auto_block_size

            block_size = _auto_block_size(
                [es.slot for es, _ in streams], lane, cut_every=eval_every
            )
        if block_size > 1:
            from repro.core import EventBlocks, blocked_inputs_batch

            blocks = [
                EventBlocks.from_stream(es, block_size, cut_every=eval_every,
                                        method=segmentation)
                for es, _ in streams
            ]
            Jb, slotb, scb, kb, maskb, chunk_blocks, n_chunks = (
                blocked_inputs_batch(blocks, [sc for _, sc in streams],
                                     eval_every)
            )
            runner = jit_runner(
                clients.device_grad, C, eval_fn=acc_fn,
                block_size=block_size, vmap_streams=True,
                donate=jax.default_backend() != "cpu",
                lane_devices=lane,
            )
            w_final, evals = runner(
                w0, jnp.asarray(Jb), jnp.asarray(slotb), jnp.asarray(scb),
                jnp.asarray(kb), jnp.asarray(maskb),
                chunk_blocks=chunk_blocks, n_chunks=n_chunks,
            )
        else:
            if lane > 1:
                raise ValueError(
                    "devices > 1 lane-shards micro-blocks and requires "
                    "block_size > 1"
                )
            Js = np.stack([es.J for es, _ in streams])
            slots = np.stack([es.slot for es, _ in streams])
            scales = np.stack([sc for _, sc in streams])
            runner = jit_runner(
                clients.device_grad, C, eval_fn=acc_fn, eval_every=eval_every,
                vmap_streams=True,
            )
            w_final, evals = runner(
                w0, jnp.asarray(Js), jnp.asarray(slots), jnp.asarray(scales)
            )

    final_acc = np.asarray(jax.jit(jax.vmap(acc_fn))(w_final))
    evals = np.asarray(evals)
    n_evals = evals.shape[1]
    eval_steps = (np.arange(n_evals) + 1) * eval_every
    eval_times = t_phys[:, eval_every - 1 :: eval_every][:, :n_evals]
    return MatrixResult(
        seeds=seeds,
        policies=policies,
        speed_ratios=speed_ratios,
        eval_steps=eval_steps,
        eval_acc=evals.reshape(S, P, H, n_evals),
        eval_times=eval_times.reshape(S, P, H, n_evals),
        final_acc=final_acc.reshape(S, P, H),
        p_vectors=p_vectors,
        extras=extras,
    )
