"""Federated runtime: wires jitted JAX client gradients into the
Generalized-AsyncSGD server loop (repro.core.async_sgd).

The engine owns:
  * a client set — each client holds a data shard and a jitted grad fn,
  * the sampling policy — uniform / Jackson-optimal / physical-time-optimal
    (computed from the client speeds via repro.core.sampling),
  * the server algorithms — Generalized AsyncSGD, AsyncSGD, FedBuff, FedAvg,
  * metrics — accuracy/loss vs CS steps *and* physical time, per-node delays.

This is the paper's deep-learning experiment (§5) as a library.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import (
    BoundConstants,
    ServerConfig,
    optimize_two_cluster,
    run_favano,
    run_fedavg,
    run_fedbuff,
    run_generalized_async_sgd,
)
from repro.data.pipeline import FederatedClassification, make_client_speeds

__all__ = ["MLPClassifier", "FLClients", "FLRun", "run_experiment", "sampling_for"]


# ------------------------------------------------------------------ #
# a small classifier in the same param-meta system as the big models
# ------------------------------------------------------------------ #
class MLPClassifier:
    """2-hidden-layer MLP; the FL-scale model (paper used ResNet20/CIFAR)."""

    def __init__(self, dim: int, num_classes: int, hidden: int = 128, seed: int = 0):
        k = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(k, 3)
        s1, s2 = 1.0 / np.sqrt(dim), 1.0 / np.sqrt(hidden)
        self.init_params = {
            "w1": jax.random.normal(k1, (dim, hidden), jnp.float32) * s1,
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (hidden, hidden), jnp.float32) * s2,
            "b2": jnp.zeros((hidden,), jnp.float32),
            "w3": jax.random.normal(k3, (hidden, num_classes), jnp.float32) * s2,
            "b3": jnp.zeros((num_classes,), jnp.float32),
        }

    @staticmethod
    def logits(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        h = jax.nn.relu(h @ params["w2"] + params["b2"])
        return h @ params["w3"] + params["b3"]

    @staticmethod
    def loss(params, batch):
        lg = MLPClassifier.logits(params, batch["x"])
        lp = jax.nn.log_softmax(lg)
        return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None], axis=-1))


class FLClients:
    """GradientSource over a federated dataset with one jitted grad fn."""

    def __init__(self, data: FederatedClassification, model: MLPClassifier, batch_size: int = 128):
        self.data = data
        self.model = model
        self.batch_size = batch_size
        self._grad = jax.jit(jax.grad(model.loss))
        self.grad_calls = 0

    def grad(self, client_id: int, params, server_step: int):
        batch = self.data.client_batch(client_id, self.batch_size)
        self.grad_calls += 1
        return self._grad(params, {"x": jnp.asarray(batch["x"]), "y": jnp.asarray(batch["y"])})


# ------------------------------------------------------------------ #
def sampling_for(flc: FLConfig, mu: np.ndarray, constants: BoundConstants | None = None) -> np.ndarray:
    """Sampling probabilities per the configured policy."""
    n = flc.n_clients
    if flc.sampling == "uniform":
        return np.full(n, 1.0 / n)
    k = constants or BoundConstants(C=flc.concurrency, T=flc.server_steps)
    mu_f, mu_s = float(mu.max()), float(mu.min())
    n_f = int(np.sum(mu > (mu_f + mu_s) / 2))
    if mu_f == mu_s or n_f in (0, n):
        return np.full(n, 1.0 / n)
    if flc.sampling == "optimal":
        res = optimize_two_cluster(mu_f, mu_s, n, n_f, k)
    elif flc.sampling == "physical_time":
        from repro.core import optimize_physical_time

        res = optimize_physical_time(mu_f, mu_s, n, n_f, k)
    else:
        raise ValueError(flc.sampling)
    # res.p has fast-first layout; map onto actual fast/slow indices
    p = np.empty(n)
    p_fast, p_slow = res.p[0], res.p[-1]
    p[mu > (mu_f + mu_s) / 2] = p_fast
    p[mu <= (mu_f + mu_s) / 2] = p_slow
    return p / p.sum()


@dataclass
class FLRun:
    name: str
    eval_steps: np.ndarray
    eval_acc: np.ndarray
    eval_times: np.ndarray
    mean_delays: np.ndarray | None = None
    final_params: Any = None
    extras: dict = field(default_factory=dict)


def _accuracy_fn(model: MLPClassifier, data: FederatedClassification, batch: int = 2048):
    ev = data.eval_batch(batch)
    x, y = jnp.asarray(ev["x"]), jnp.asarray(ev["y"])

    @jax.jit
    def acc(params):
        return jnp.mean(jnp.argmax(MLPClassifier.logits(params, x), -1) == y)

    return lambda p: float(acc(p))


def run_experiment(
    flc: FLConfig,
    method: str,
    eta: float = 0.05,
    eval_every: int = 10,
    data: FederatedClassification | None = None,
) -> FLRun:
    """One training run of {gen_async, async_sgd, fedbuff, fedavg}."""
    data = data or FederatedClassification(n_clients=flc.n_clients, seed=flc.seed)
    model = MLPClassifier(data.dim, data.num_classes, seed=flc.seed)
    clients = FLClients(data, model)
    mu = make_client_speeds(flc.n_clients, flc.frac_fast, flc.speed_ratio, seed=flc.seed)
    acc_fn = _accuracy_fn(model, data)

    base = ServerConfig(
        n=flc.n_clients,
        C=flc.concurrency,
        T=flc.server_steps,
        eta=eta,
        mu=mu,
        service=flc.service,
        seed=flc.seed,
        eval_every=eval_every,
    )

    if method == "gen_async":
        p = sampling_for(flc, mu)
        cfg = ServerConfig(**{**base.__dict__, "p": p, "weighting": "importance"})
        w, tr = run_generalized_async_sgd(model.init_params, clients, cfg, eval_fn=acc_fn)
    elif method == "async_sgd":
        cfg = ServerConfig(**{**base.__dict__, "weighting": "plain"})
        w, tr = run_generalized_async_sgd(model.init_params, clients, cfg, eval_fn=acc_fn)
    elif method == "fedbuff":
        cfg = ServerConfig(**{**base.__dict__, "weighting": "plain"})
        w, tr = run_fedbuff(model.init_params, clients, cfg, Z=flc.fedbuff_Z, eval_fn=acc_fn)
    elif method == "fedavg":
        cfg = ServerConfig(**{**base.__dict__, "weighting": "plain"})
        w, tr = run_fedavg(model.init_params, clients, cfg, eval_fn=acc_fn)
    elif method == "favano":
        cfg = ServerConfig(**{**base.__dict__, "weighting": "plain"})
        w, tr = run_favano(model.init_params, clients, cfg,
                           period=1.0 / float(np.median(mu)), eval_fn=acc_fn)
    else:
        raise ValueError(method)

    ev_steps = np.asarray(tr.eval_steps)
    times = (
        np.asarray([tr.times[min(s - 1, len(tr.times) - 1)] for s in tr.eval_steps])
        if len(tr.eval_steps)
        else np.array([])
    )
    delays = None
    if tr.delays is not None:
        delays = np.array([np.mean(d) if d else np.nan for d in tr.delays])
    return FLRun(
        name=method,
        eval_steps=ev_steps,
        eval_acc=np.asarray(tr.eval_values),
        eval_times=times,
        mean_delays=delays,
        final_params=w,
        extras={"grad_calls": clients.grad_calls},
    )
