from .engine import (
    ClassificationTask,
    DeviceFLClients,
    DeviceTaskClients,
    FLClients,
    FLRun,
    LMTask,
    MatrixResult,
    MLPClassifier,
    TaskSetup,
    run_experiment,
    run_matrix,
    sampling_for,
)
