from .engine import (
    DeviceFLClients,
    FLClients,
    FLRun,
    MatrixResult,
    MLPClassifier,
    run_experiment,
    run_matrix,
    sampling_for,
)
