from .engine import FLClients, FLRun, MLPClassifier, run_experiment, sampling_for
