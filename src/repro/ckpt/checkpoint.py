"""Pytree checkpointing: npz arrays + json tree structure, with rotation.

No orbax offline; this is a compact, restartable format:
  <dir>/step_<k>/arrays.npz     flattened leaves, keys = tree paths
  <dir>/step_<k>/meta.json      treedef repr, shapes/dtypes, user metadata
Atomic via tmp-dir rename.  `latest_step`/`restore` round-trip any pytree of
arrays (params, optimizer state, server state).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "available_steps", "load_metadata"]

_SEP = "|"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _encode(flat: dict[str, np.ndarray]):
    """Make every leaf `np.load`-able.

    ml_dtypes leaves (bfloat16 & friends) have ``dtype.kind == 'V'``:
    `np.savez` writes them but `np.load` cannot read the structured void
    dtype back.  Store such leaves as a same-width unsigned-int bit view and
    record the true dtype name in meta.json (``encoded_dtypes``); `restore`
    views the bits back, so the round trip is exact.
    """
    out: dict[str, np.ndarray] = {}
    encoded: dict[str, str] = {}
    for key, arr in flat.items():
        if arr.dtype.kind == "V":
            encoded[key] = arr.dtype.name
            out[key] = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        else:
            out[key] = arr
    return out, encoded


def save(
    directory: str,
    step: int,
    tree: Any,
    metadata: dict | None = None,
    keep: int = 3,
) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat = _flatten(tree)
        stored, encoded = _encode(flat)
        np.savez(os.path.join(tmp, "arrays.npz"), **stored)
        treedef = jax.tree_util.tree_structure(tree)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(flat),
            "encoded_dtypes": encoded,
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # rotate
    steps = available_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"), ignore_errors=True)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def load_metadata(directory: str, step: int) -> dict:
    """The user ``metadata`` dict a checkpoint was saved with."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f).get("metadata", {})


def restore(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shapes validated)."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        encoded = json.load(f).get("encoded_dtypes", {})
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {
            k: z[k].view(np.dtype(encoded[k])) if k in encoded else z[k]
            for k in z.files
        }
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_like = _flatten(like)
    if sorted(flat_like) != sorted(flat):
        missing = set(flat_like) - set(flat)
        extra = set(flat) - set(flat_like)
        raise ValueError(f"checkpoint tree mismatch: missing={missing} extra={extra}")
    keys_in_order = list(flat_like)
    new_leaves = []
    for key, leaf in zip(keys_in_order, leaves_like):
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
