from .pipeline import FederatedClassification, SyntheticLMStream, make_client_speeds
