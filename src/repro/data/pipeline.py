"""Data pipeline: synthetic LM stream + non-iid federated classification.

CIFAR-10 / TinyImageNet are not available offline; the federated experiments
use a synthetic classification task with the *same heterogeneity mechanism*
as the paper (each client holds a subset of classes — 7 of 10 in the paper's
CIFAR split), and the LM path uses a Zipf-distributed token stream with
Markov structure so losses are informative (not flat noise).

Everything is deterministic given a seed, streaming (no dataset
materialization), and host-side numpy feeding jitted device steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["SyntheticLMStream", "FederatedClassification", "make_client_speeds"]


class SyntheticLMStream:
    """Zipf unigram + first-order Markov bigram token stream.

    A random sparse transition structure makes next-token prediction
    learnable: loss decreases materially within a few hundred steps on a
    small model.
    """

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0, branch: int = 8):
        self.V, self.S = vocab_size, seq_len
        self.rng = np.random.default_rng(seed)
        # each token has `branch` likely successors (shared structure)
        self.succ = self.rng.integers(0, vocab_size, size=(vocab_size, branch))
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch(self, batch_size: int) -> dict:
        B, S = batch_size, self.S
        toks = np.zeros((B, S + 1), dtype=np.int32)
        toks[:, 0] = self.rng.choice(self.V, size=B, p=self.unigram)
        follow = self.rng.random((B, S)) < 0.85
        nxt_choice = self.rng.integers(0, self.succ.shape[1], size=(B, S))
        rand_tok = self.rng.choice(self.V, size=(B, S), p=self.unigram)
        for t in range(S):
            markov = self.succ[toks[:, t], nxt_choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], markov, rand_tok[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class FederatedClassification:
    """Prototype-mixture classification, split non-iid across n clients.

    Each class c has a prototype vector; x = prototype[y] + noise.  Client i
    sees `classes_per_client` of the `num_classes` classes (paper: 7 of 10),
    drawn without replacement per client — heterogeneous G^2 > 0.
    """

    n_clients: int = 100
    num_classes: int = 10
    dim: int = 64
    classes_per_client: int = 7
    noise: float = 0.8
    seed: int = 0
    _protos: np.ndarray = field(init=False, repr=False)
    _client_classes: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._protos = rng.normal(size=(self.num_classes, self.dim))
        self._protos /= np.linalg.norm(self._protos, axis=1, keepdims=True)
        self._client_classes = np.stack(
            [
                rng.choice(self.num_classes, size=self.classes_per_client, replace=False)
                for _ in range(self.n_clients)
            ]
        )
        self._rngs = [np.random.default_rng(self.seed * 7919 + 31 * i + 1) for i in range(self.n_clients)]
        self._eval_rng = np.random.default_rng(self.seed + 10_007)

    def client_batch(self, client: int, batch_size: int) -> dict:
        rng = self._rngs[client]
        ys = rng.choice(self._client_classes[client], size=batch_size)
        xs = self._protos[ys] + self.noise * rng.normal(size=(batch_size, self.dim))
        return {"x": xs.astype(np.float32), "y": ys.astype(np.int32)}

    def eval_batch(self, batch_size: int) -> dict:
        """IID draw over all classes — the central server's validation set."""
        ys = self._eval_rng.choice(self.num_classes, size=batch_size)
        xs = self._protos[ys] + self.noise * self._eval_rng.normal(size=(batch_size, self.dim))
        return {"x": xs.astype(np.float32), "y": ys.astype(np.int32)}

    def device_shards(self, samples_per_client: int) -> tuple[np.ndarray, np.ndarray]:
        """Materialize fixed-size per-client datasets as stacked arrays.

        Returns (x, y) with shapes (n_clients, m, dim) / (n_clients, m) —
        the device-resident form the compiled scan engine gathers minibatches
        from (client axis indexed by the traced J_k).  Deterministic given the
        dataset seed and independent of the streaming `client_batch` RNG state.
        """
        m = int(samples_per_client)
        xs = np.empty((self.n_clients, m, self.dim), np.float32)
        ys = np.empty((self.n_clients, m), np.int32)
        for i in range(self.n_clients):
            rng = np.random.default_rng(self.seed * 104_729 + 613 * i + 7)
            yi = rng.choice(self._client_classes[i], size=m)
            xs[i] = self._protos[yi] + self.noise * rng.normal(size=(m, self.dim))
            ys[i] = yi
        return xs, ys


def make_client_speeds(
    n: int, frac_fast: float, speed_ratio: float, mu_slow: float = 1.0, seed: int = 0
) -> np.ndarray:
    """Paper's 2-cluster speed assignment: fast clients are `speed_ratio`x faster."""
    rng = np.random.default_rng(seed)
    n_fast = int(round(n * frac_fast))
    mu = np.full(n, mu_slow)
    fast_idx = rng.choice(n, size=n_fast, replace=False)
    mu[fast_idx] = mu_slow * speed_ratio
    return mu
